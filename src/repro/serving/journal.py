"""Write-ahead serving journal for power-failure-atomic execution.

Antler's flagship platform (a batteryless MSP430FR5994) loses power as a
matter of course; what survives is the FRAM.  This module is the serving
stack's FRAM: a small append-only **write-ahead journal** the session writes
*before* acting, so that after a whole-process power failure a fresh session
(:meth:`~repro.serving.session.ServingSession.recover`) can reconstruct

* the admission queue — every admitted request is journaled at submit, so a
  crash never loses a request that was acknowledged;
* exactly-once responses — a group's outputs are journaled atomically at
  **commit**; a committed group is never re-run (its responses are rebuilt
  from the journal), an uncommitted group is re-run in full;
* the executor's weight residency — committed alongside each group, the
  "weights live in the durable tier" model of the paper's FRAM deployment;
* mid-suffix progress — segmented fused suffixes write an
  **activation checkpoint** at cost-model-chosen block-depth boundaries
  (``GraphCostModel.plan_checkpoints``), so an inference interrupted at
  block ``d`` resumes from ``d``, not from 0.

Replay (:meth:`Journal.replay`) is a pure, idempotent fold over the record
stream: replaying twice — or replaying a journal that already contains a
recovery's own records — produces the same :class:`JournalState`.
Duplicate commits for one group are ignored after the first, which is the
exactly-once guarantee.

Two stores: :class:`MemoryJournalStore` (the simulation's FRAM — it outlives
the session object the way FRAM outlives a power cycle) and
:class:`FileJournalStore` (JSON-lines on disk, fsync'd per record, arrays
round-tripped losslessly), selected per :class:`Journal`.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.types import ExecutionStats, NodeId

__all__ = [
    "Journal",
    "JournalState",
    "JournalStore",
    "MemoryJournalStore",
    "FileJournalStore",
]


# ------------------------------------------------------------------ stores
class JournalStore:
    """Append-only durable record store (the "FRAM" interface).

    ``append`` must be atomic at record granularity: a record is either
    durably present in ``records()`` after ``append`` returns, or absent —
    never torn.  Both built-in stores satisfy this trivially (list append;
    single-line write + flush + fsync).
    """

    def append(self, record: Dict[str, Any]) -> None:
        raise NotImplementedError

    def records(self) -> List[Dict[str, Any]]:
        raise NotImplementedError


class MemoryJournalStore(JournalStore):
    """In-memory store: the simulated nonvolatile tier.

    The intermittent benchmark keeps this object *outside* the session, so
    it survives the simulated power failure exactly as FRAM survives a real
    one, while the session (SRAM) is rebuilt from scratch.
    """

    def __init__(self) -> None:
        self._records: List[Dict[str, Any]] = []

    def append(self, record: Dict[str, Any]) -> None:
        self._records.append(record)

    def records(self) -> List[Dict[str, Any]]:
        return list(self._records)

    def __len__(self) -> int:
        return len(self._records)


class FileJournalStore(JournalStore):
    """JSON-lines file store, fsync'd per record.

    Arrays are encoded as ``{"__ndarray__": {dtype, shape, data}}`` leaves
    and decoded back to ``numpy`` on read, so a journal written before a
    real process death replays bit-exactly (for integer dtypes) or
    value-exactly (floats round-trip through ``tolist`` at full repr
    precision via JSON).
    """

    def __init__(self, path: str) -> None:
        self.path = str(path)

    def append(self, record: Dict[str, Any]) -> None:
        line = json.dumps(_encode(record), separators=(",", ":"))
        with open(self.path, "a", encoding="utf-8") as fh:
            fh.write(line + "\n")
            fh.flush()
            os.fsync(fh.fileno())

    def records(self) -> List[Dict[str, Any]]:
        if not os.path.exists(self.path):
            return []
        out: List[Dict[str, Any]] = []
        with open(self.path, "r", encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if line:
                    out.append(_decode(json.loads(line)))
        return out


def _encode(obj: Any) -> Any:
    """Recursively encode a record for JSON (arrays -> tagged leaves)."""
    if isinstance(obj, np.ndarray):
        return {
            "__ndarray__": {
                "dtype": obj.dtype.name,
                "shape": list(obj.shape),
                "data": obj.ravel().tolist(),
            }
        }
    if isinstance(obj, dict):
        return {k: _encode(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_encode(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    return obj


def _decode(obj: Any) -> Any:
    """Inverse of :func:`_encode` (JSON lists stay lists)."""
    if isinstance(obj, dict):
        if "__ndarray__" in obj and len(obj) == 1:
            spec = obj["__ndarray__"]
            return np.asarray(
                spec["data"], dtype=np.dtype(spec["dtype"])
            ).reshape(spec["shape"])
        return {k: _decode(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_decode(v) for v in obj]
    return obj


# ------------------------------------------------------------- (de)coding
def _encode_node(node: Optional[NodeId]) -> Optional[List[Any]]:
    if node is None:
        return None
    depth, group = node
    return [int(depth), [int(t) for t in group]]


def _decode_node(enc: Optional[Sequence[Any]]) -> Optional[NodeId]:
    if enc is None:
        return None
    depth, group = enc
    return (int(depth), tuple(int(t) for t in group))


def _as_host(value: Any) -> np.ndarray:
    """Materialise a (possibly device) array on the host for journaling."""
    return np.asarray(value)


# -------------------------------------------------------------- journal
class Journal:
    """The serving session's write-ahead journal over a pluggable store.

    Record kinds (in the order a healthy group produces them)::

        admit         request acknowledged (payload: input, tasks, SLOs)
        group_begin   group planned: members, execution order, valid rows
        checkpoint    mid-suffix activation at a block-depth commit point
        group_commit  outputs + counters + post-group residency, atomically
        request_failed  request reached a durable non-response outcome

    Writers call the typed methods; readers call :meth:`replay`.
    """

    def __init__(self, store: Optional[JournalStore] = None) -> None:
        self.store = store if store is not None else MemoryJournalStore()

    # -------------------------------------------------------------- writes
    def admit(
        self,
        seq: int,
        x: Any,
        tasks: Optional[Sequence[int]],
        deadline: Optional[float] = None,
        priority: int = 0,
        tenant: Optional[str] = None,
    ) -> None:
        self.store.append({
            "kind": "admit",
            "seq": int(seq),
            "x": _as_host(x),
            "tasks": None if tasks is None else [int(t) for t in tasks],
            "deadline": None if deadline is None else float(deadline),
            "priority": int(priority),
            "tenant": tenant,
        })

    def request_failed(self, seq: int) -> None:
        """A durable terminal non-response outcome (expired, shed, or the
        group ladder ran out): recovery must not resurrect this request."""
        self.store.append({"kind": "request_failed", "seq": int(seq)})

    def group_begin(
        self,
        group_id: int,
        seqs: Sequence[int],
        order: Sequence[int],
        valid: int,
    ) -> None:
        self.store.append({
            "kind": "group_begin",
            "group_id": int(group_id),
            "seqs": [int(s) for s in seqs],
            "order": [int(t) for t in order],
            "valid": int(valid),
        })

    def checkpoint(
        self,
        group_id: int,
        pos: int,
        task: int,
        depth: int,
        node: NodeId,
        value: Any,
        act_shape: Optional[Sequence[int]],
    ) -> None:
        """One mid-suffix activation checkpoint: the suffix of ``task`` (at
        position ``pos`` of the group's order) committed through block
        ``depth``."""
        self.store.append({
            "kind": "checkpoint",
            "group_id": int(group_id),
            "pos": int(pos),
            "task": int(task),
            "depth": int(depth),
            "node": _encode_node(node),
            "value": _as_host(value),
            "act_shape": (
                None if act_shape is None else [int(s) for s in act_shape]
            ),
        })

    def group_commit(
        self,
        group_id: int,
        seqs: Sequence[int],
        outputs: Sequence[Dict[int, Any]],
        residency: Sequence[Optional[NodeId]],
        stats: ExecutionStats,
    ) -> None:
        """Atomically commit one executed group.

        ``outputs`` is per-slot (one dict per valid member, aligned with
        ``seqs``); ``residency`` the executor's post-group residency (the
        journaled residency *transition*); ``stats`` the group's executed
        counters.  One appended record = one atomic commit: either recovery
        sees the whole group (and never re-runs it) or none of it (and
        re-runs it in full).
        """
        self.store.append({
            "kind": "group_commit",
            "group_id": int(group_id),
            "seqs": [int(s) for s in seqs],
            "outputs": [
                [[int(t), _as_host(v)] for t, v in sorted(slot.items())]
                for slot in outputs
            ],
            "residency": [_encode_node(n) for n in residency],
            "stats": dataclasses.asdict(stats),
        })

    # --------------------------------------------------------------- reads
    def replay(self) -> "JournalState":
        """Fold the record stream into recovered state, idempotently.

        Pure with respect to the store (no writes); tolerant of duplicate
        records — the first ``group_commit`` per group wins, later ones are
        ignored, and an ``admit`` for an already-admitted seq is a no-op.
        """
        admitted: Dict[int, Dict[str, Any]] = {}
        terminal: Set[int] = set()
        responses: Dict[int, Dict[str, Any]] = {}
        committed: Set[int] = set()
        residency: Optional[List[Optional[NodeId]]] = None
        open_groups: Dict[int, Dict[str, Any]] = {}
        checkpoints: Dict[int, Dict[str, Any]] = {}
        next_group_id = 0
        for rec in self.store.records():
            kind = rec.get("kind")
            if kind == "admit":
                admitted.setdefault(int(rec["seq"]), rec)
            elif kind == "request_failed":
                terminal.add(int(rec["seq"]))
            elif kind == "group_begin":
                gid = int(rec["group_id"])
                next_group_id = max(next_group_id, gid + 1)
                if gid not in committed:
                    open_groups[gid] = rec
            elif kind == "checkpoint":
                gid = int(rec["group_id"])
                if gid not in committed:
                    checkpoints[gid] = rec
            elif kind == "group_commit":
                gid = int(rec["group_id"])
                next_group_id = max(next_group_id, gid + 1)
                if gid in committed:
                    continue  # duplicate commit: exactly-once, first wins
                committed.add(gid)
                open_groups.pop(gid, None)
                checkpoints.pop(gid, None)
                residency = [_decode_node(n) for n in rec["residency"]]
                stats = ExecutionStats(**rec["stats"])
                for slot, seq in enumerate(rec["seqs"]):
                    seq = int(seq)
                    terminal.add(seq)
                    responses.setdefault(seq, {
                        "group_id": gid,
                        "outputs": {
                            int(t): v for t, v in rec["outputs"][slot]
                        },
                        "stats": stats,
                        "group_size": len(rec["seqs"]),
                    })
            else:
                raise ValueError(f"unknown journal record kind {kind!r}")
        # The in-flight group: the *latest* begun-but-uncommitted group.
        # (At most one can genuinely be in flight — the session journals
        # begin/commit strictly around each group's execution.)
        inflight: Optional[Dict[str, Any]] = None
        if open_groups:
            gid = max(open_groups)
            inflight = open_groups[gid]
        checkpoint = (
            checkpoints.get(int(inflight["group_id"])) if inflight else None
        )
        return JournalState(
            admitted=admitted,
            terminal=terminal,
            responses=responses,
            residency=residency,
            inflight=inflight,
            checkpoint=checkpoint,
            next_group_id=next_group_id,
        )


@dataclasses.dataclass
class JournalState:
    """What :meth:`Journal.replay` recovers from the record stream.

    ``pending_seqs`` is the derived admission backlog: admitted requests
    with no durable terminal outcome, in admission order — exactly what a
    recovering session must re-enqueue.
    """

    admitted: Dict[int, Dict[str, Any]]
    terminal: Set[int]
    responses: Dict[int, Dict[str, Any]]
    residency: Optional[List[Optional[NodeId]]]
    inflight: Optional[Dict[str, Any]]
    checkpoint: Optional[Dict[str, Any]]
    next_group_id: int

    @property
    def pending_seqs(self) -> List[int]:
        return [s for s in sorted(self.admitted) if s not in self.terminal]

    def checkpoint_node(self) -> Optional[NodeId]:
        if self.checkpoint is None:
            return None
        return _decode_node(self.checkpoint["node"])
