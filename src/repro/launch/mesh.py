"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  The single-pod mesh is 16x16 = 256 chips
(data, model); the multi-pod mesh is 2x16x16 = 512 chips (pod, data, model)
where "pod" is an additional data-parallel axis whose collectives cross the
inter-pod (DCN-class) links.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist on newer jax;
    older releases build the same Auto-typed mesh without the argument.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` on newer jax; on older releases ``Mesh`` itself is the
    context manager that installs the legacy global mesh.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke usage (axes present but size 1)."""
    return make_mesh((1, 1), ("data", "model"))
