"""Distributed training launcher.

On real hardware this runs under the production mesh; on this CPU container
it runs the same code path on a 1x1 mesh with a reduced config — the
mesh/sharding plumbing is identical (the dry-run proves the production mesh
lowers).

  PYTHONPATH=src python -m repro.launch.train --arch granite-34b --smoke \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_smoke_config, list_archs
from repro.data import lm_batches
from repro.launch.mesh import make_host_mesh, make_production_mesh, set_mesh
from repro.models import get_model
from repro.sharding.policy import TP_POLICY
from repro.sharding.utils import fit_specs
from repro.training import (
    AdamWConfig, adamw_init, make_train_step, save_checkpoint,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="granite-34b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None, help="checkpoint path to save")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (needs 256 devices)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = (
        make_production_mesh() if args.production_mesh else make_host_mesh()
    )
    policy = TP_POLICY
    model = get_model(cfg)

    with set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        pspec = fit_specs(params, model.param_specs(policy), mesh)
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            params, pspec, is_leaf=lambda v: hasattr(v, "shape"),
        )
        opt = adamw_init(params)
        opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                              total_steps=args.steps)
        step_fn = jax.jit(make_train_step(model, opt_cfg, policy))
        it = lm_batches(cfg.vocab_size, args.batch, args.seq, seed=0)

        t0 = time.perf_counter()
        for step in range(args.steps):
            tokens = jnp.asarray(next(it))
            if cfg.family == "encdec":
                feats = jnp.asarray(np.random.default_rng(step).normal(
                    size=(args.batch, args.seq, cfg.enc_inputs)
                ).astype(np.float32))
                batch = {"features": feats, "tokens": tokens}
            else:
                batch = tokens
            params, opt, metrics = step_fn(params, opt, batch)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({time.perf_counter()-t0:.0f}s)")
        if args.ckpt:
            save_checkpoint(args.ckpt, {"params": params}, step=args.steps)
            print(f"saved {args.ckpt}")


if __name__ == "__main__":
    main()
