import os
# Append rather than overwrite: the user's own XLA_FLAGS (dump dirs, CPU
# feature flags, a test harness's device forcing) must survive.  Skip when a
# device count is already forced — jax locks it at first init anyway.
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=512"
    ).strip()

"""Multi-pod dry-run driver (deliverable e).

Lowers + compiles the appropriate step function (train_step / prefill_step /
serve_step) for every requested (architecture x input-shape) combination on
the production meshes — 16x16 single-pod and 2x16x16 multi-pod — and writes
memory_analysis / cost_analysis / roofline terms to JSON.

The XLA_FLAGS line above MUST precede every other import (jax locks the
device count at first init), which is why this module sets it at line 1-2
(and why `from __future__` cannot be used here).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-34b \
      --shape train_4k --mesh single --out reports/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all   # every combo, serial
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_production_mesh, set_mesh
from repro.launch.roofline import (
    RooflineReport, active_params, model_flops_estimate,
)
from repro.launch.specs import config_for_shape, make_plan, shape_supported
from repro.models.config import INPUT_SHAPES, get_shape
from repro.sharding.utils import tree_bytes


def run_one(
    arch: str,
    shape_name: str,
    mesh_kind: str = "single",
    policy: str = "auto",
    out_dir: Optional[str] = None,
    verbose: bool = True,
) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = shape_supported(config_for_shape(cfg, shape), shape)
    if not ok:
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                  "status": "skipped", "reason": why}
        _write(result, out_dir, arch, shape_name, mesh_kind)
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    # Monotonic clock: these are durations, and time.time() can jump under
    # NTP adjustment mid-compile.
    t0 = time.perf_counter()
    try:
        with set_mesh(mesh):
            plan = make_plan(cfg, shape, mesh, policy)
            # Decode updates its cache in place (§Perf C3): donating the
            # cache argument lets XLA alias the output buffer.
            donate = (2,) if plan.kind == "decode" else ()
            jitted = jax.jit(
                plan.step_fn,
                in_shardings=plan.in_shardings,
                out_shardings=plan.out_shardings,
                donate_argnums=donate,
            )
            lowered = jitted.lower(*plan.args_sds)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
    except Exception as e:  # lowering/compile failures are bugs: surface them
        result = {
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "status": "error", "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
        _write(result, out_dir, arch, shape_name, mesh_kind)
        if verbose:
            print(json.dumps({k: result[k] for k in ("arch", "shape", "mesh", "status", "error")}))
        return result

    chips = int(np.prod(list(mesh.shape.values())))
    # Trip-count-aware HLO walk (XLA's cost_analysis counts while bodies
    # once; see launch/hlo_cost.py).  The HLO module is the per-device
    # program, so flops/bytes here are PER CHIP.
    acc = analyze_hlo(hlo)
    coll = {k.replace("coll_", ""): v for k, v in acc.items() if k.startswith("coll_")}
    coll["total"] = acc["collective_bytes"]
    flops = acc["flops"] * chips          # aggregate FLOPs across chips
    bts = acc["bytes"] * chips

    n_params = int(
        tree_bytes(plan.args_sds[0])
        / np.dtype(plan.cfg.param_dtype).itemsize
    )
    n_active = active_params(plan.cfg, n_params)
    mf = model_flops_estimate(plan.cfg, shape, n_params, n_active)

    mem_d = _mem_dict(mem)
    report = RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_kind, chips=chips,
        hlo_flops=flops, hlo_bytes=bts,
        coll_bytes=coll["total"], coll_breakdown=coll,
        model_flops=mf,
        bytes_per_device=float(mem_d.get("argument_size_in_bytes", 0.0)),
        peak_memory_per_device=float(
            mem_d.get("temp_size_in_bytes", 0.0)
            + mem_d.get("argument_size_in_bytes", 0.0)
            + mem_d.get("output_size_in_bytes", 0.0)
        ),
    )
    result = {
        "status": "ok",
        "kind": plan.kind,
        "policy": plan.policy.name,
        "n_params": n_params,
        "n_active_params": n_active,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_analysis": mem_d,
        "cost_analysis": {k: float(v) for k, v in (cost or {}).items()
                          if isinstance(v, (int, float))},
        **report.to_dict(),
    }
    _write(result, out_dir, arch, shape_name, mesh_kind)
    if verbose:
        print(json.dumps({
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "status": "ok", "policy": plan.policy.name,
            "params_B": round(n_params / 1e9, 2),
            "t_compute": f"{report.t_compute:.4f}",
            "t_memory": f"{report.t_memory:.4f}",
            "t_collective": f"{report.t_collective:.4f}",
            "dominant": report.dominant,
            "useful": f"{report.useful_flops_ratio:.3f}",
            "compile_s": result["compile_s"],
        }))
    return result


def _mem_dict(mem) -> dict:
    out = {}
    for attr in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        try:
            out[attr] = float(getattr(mem, attr))
        except (AttributeError, TypeError):
            # Only the expected shape mismatches across jaxlib versions: a
            # missing accessor or a non-numeric return.  Anything else
            # (e.g. a RuntimeError from a dead backend) should surface.
            pass
    if not out and mem is not None:
        out["repr"] = str(mem)[:2000]
    return out


def _write(result: dict, out_dir: Optional[str], arch: str, shape: str, mesh: str):
    if out_dir is None:
        return
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=[s.name for s in INPUT_SHAPES])
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--policy", choices=["auto", "tp", "fsdp_tp", "expert_tp", "fsdp_expert"], default="auto")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--all", action="store_true", help="run every combo serially")
    args = ap.parse_args()

    if args.all:
        for arch in list_archs():
            for shape in INPUT_SHAPES:
                for mesh in ("single", "multi"):
                    run_one(arch, shape.name, mesh, args.policy, args.out)
        return
    if not args.arch or not args.shape:
        ap.error("--arch and --shape are required (or use --all)")
    run_one(args.arch, args.shape, args.mesh, args.policy, args.out)


if __name__ == "__main__":
    main()
