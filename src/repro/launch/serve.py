"""Serving launcher: batched prefill + greedy decode for any arch.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x22b --smoke \
      --batch 4 --prompt-len 16 --steps 24
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config, list_archs
from repro.launch.mesh import make_host_mesh, make_production_mesh, set_mesh
from repro.models import get_model
from repro.serving import LMServer
from repro.sharding.policy import TP_POLICY


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="granite-34b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_production_mesh() if args.production_mesh else make_host_mesh()
    model = get_model(cfg)

    with set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        srv = LMServer(model, params, TP_POLICY)
        rng = np.random.default_rng(0)
        prompts = jnp.asarray(
            rng.integers(0, cfg.raw_vocab_size, (args.batch, args.prompt_len)),
            jnp.int32,
        )
        feats = None
        if cfg.family == "encdec":
            feats = jnp.asarray(rng.normal(
                size=(args.batch, args.prompt_len, cfg.enc_inputs)
            ).astype(np.float32))
        t0 = time.perf_counter()  # monotonic: NTP can step time.time()
        out = srv.generate(prompts, steps=args.steps, features=feats)
        dt = time.perf_counter() - t0
        print(f"arch={cfg.name} generated {out.shape[0]}x{out.shape[1]} tokens "
              f"in {dt:.1f}s ({out.size/dt:.1f} tok/s)")
        print("sample:", out[0][:16])


if __name__ == "__main__":
    main()
