"""Input/param/cache ShapeDtypeStructs + shardings for the dry-run.

``input_specs(cfg, shape)`` produces weak-type-correct ShapeDtypeStruct
stand-ins for every model input (no device allocation), and
``plan(cfg, shape, mesh, policy)`` assembles the full lowering plan: the
step function, its argument SDS tree and the in/out shardings fitted to the
mesh (``fit_specs`` drops axes that don't divide).

Policy auto-selection: per-device bytes under plain TP =
(params + optimizer if training) / model_axis; if that exceeds the HBM
budget, parameters (and optimizer moments with them) shard additionally
over the data axis (FSDP, beyond-paper iteration recorded in §Perf).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import InputShape, ModelConfig
from repro.models.registry import WHISPER_ENC_LEN, ModelApi, get_model
from repro.sharding.policy import (
    EXPERT_TP_POLICY, FSDP_EXPERT_POLICY, FSDP_TP_POLICY, ShardingPolicy,
    TP_POLICY,
)
from repro.sharding.utils import fit_specs, to_named_shardings, tree_bytes
from repro.training.optimizer import AdamWConfig, AdamWState
from repro.training.train_loop import make_train_step

HBM_PER_CHIP = 16e9  # TPU v5e


def config_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Apply the long-context SWA override for long_500k (DESIGN §5)."""
    if shape.name == "long_500k" and cfg.long_context_window is not None:
        return dataclasses.replace(cfg, sliding_window=cfg.long_context_window)
    return cfg


def shape_supported(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Principled skips (recorded in DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: long_500k needs sub-quadratic attention"
    return True, ""


def param_shapes(model: ModelApi) -> Any:
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def opt_shapes(params_sds: Any) -> AdamWState:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(f32, params_sds),
        nu=jax.tree.map(f32, params_sds),
    )


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStructs for the *data* inputs of the step function."""
    b, s = shape.global_batch, shape.seq_len
    tok = lambda shp: jax.ShapeDtypeStruct(shp, jnp.int32)
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            return {
                "batch": {
                    "features": jax.ShapeDtypeStruct(
                        (b, s, cfg.enc_inputs), jnp.dtype(cfg.dtype)
                    ),
                    "tokens": tok((b, s)),
                }
            }
        return {"batch": tok((b, s))}
    # decode: ONE new token against a cache of seq_len
    model = get_model(cfg)
    return {
        "token": tok((b,)),
        "cache": model.cache_shape(b, s),
        "cache_len": jax.ShapeDtypeStruct((), jnp.int32),
    }


def select_policy(
    cfg: ModelConfig, shape: InputShape, requested: str = "auto"
) -> ShardingPolicy:
    if requested == "tp":
        return TP_POLICY
    if requested == "fsdp_tp":
        return FSDP_TP_POLICY
    if requested == "expert_tp":
        return EXPERT_TP_POLICY
    if requested == "fsdp_expert":
        return FSDP_EXPERT_POLICY
    model = get_model(cfg)
    psds = param_shapes(model)
    pbytes = tree_bytes(psds)
    model_par = 16
    per_dev = pbytes / model_par
    if shape.kind == "train":
        per_dev += 8.0 * (pbytes / jnp.dtype(cfg.param_dtype).itemsize) / model_par
    # Leave headroom for activations / caches.
    if per_dev > 0.6 * HBM_PER_CHIP:
        return FSDP_TP_POLICY
    return TP_POLICY


@dataclasses.dataclass
class LoweringPlan:
    """Everything needed to lower one (arch x shape x mesh) combination."""

    cfg: ModelConfig
    shape: InputShape
    policy: ShardingPolicy
    step_fn: Callable
    args_sds: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    kind: str


def _compat_shardings(tree: Any, mesh: Mesh) -> Any:
    """Spec trees jit will accept on this jax version.

    Newer jax (with ``jax.set_mesh``) takes bare ``PartitionSpec``s against
    the ambient mesh; older releases require concrete ``NamedSharding``s
    (``None`` leaves stay ``None`` — unspecified is accepted everywhere).
    """
    if hasattr(jax, "set_mesh"):
        return tree
    return to_named_shardings(tree, mesh)


def _batch_spec(cfg: ModelConfig, shape: InputShape, policy: ShardingPolicy, mesh: Mesh):
    b = policy.physical("batch")
    if cfg.family == "encdec" and shape.kind in ("train", "prefill"):
        raw = {"features": P(b, None, None), "tokens": P(b, None)}
        sds = input_specs(cfg, shape)["batch"]
        return fit_specs(sds, raw, mesh)
    return fit_specs(
        input_specs(cfg, shape)["batch"], P(b, None), mesh
    )


def make_plan(
    cfg: ModelConfig,
    shape: InputShape,
    mesh: Mesh,
    policy_name: str = "auto",
) -> LoweringPlan:
    cfg = config_for_shape(cfg, shape)
    policy = select_policy(cfg, shape, policy_name)
    model = get_model(cfg)
    psds = param_shapes(model)
    pspec = fit_specs(psds, model.param_specs(policy), mesh)

    if shape.kind == "train":
        osds = opt_shapes(psds)
        ospec = AdamWState(step=P(), mu=pspec, nu=pspec)
        bsds = input_specs(cfg, shape)["batch"]
        bspec = _batch_spec(cfg, shape, policy, mesh)
        opt_cfg = AdamWConfig()
        step = make_train_step(model, opt_cfg, policy)
        out_shardings = (pspec, ospec, None)  # metrics replicated
        return LoweringPlan(
            cfg, shape, policy, step, (psds, osds, bsds),
            _compat_shardings((pspec, ospec, bspec), mesh),
            _compat_shardings(out_shardings, mesh), "train",
        )

    if shape.kind == "prefill":
        bsds = input_specs(cfg, shape)["batch"]
        bspec = _batch_spec(cfg, shape, policy, mesh)
        cache_spec = fit_specs(
            model.cache_shape(shape.global_batch, shape.seq_len),
            model.cache_spec(policy), mesh,
        )

        def prefill_step(params, batch):
            return model.prefill(params, batch, policy)

        out_shardings = (None, cache_spec)
        return LoweringPlan(
            cfg, shape, policy, prefill_step, (psds, bsds),
            _compat_shardings((pspec, bspec), mesh),
            _compat_shardings(out_shardings, mesh), "prefill",
        )

    # decode
    spec_in = input_specs(cfg, shape)
    csds = spec_in["cache"]
    cspec = fit_specs(csds, model.cache_spec(policy), mesh)
    b = policy.physical("batch")
    tok_spec = fit_specs(spec_in["token"], P(b), mesh)

    def serve_step(params, token, cache, cache_len):
        return model.decode_step(params, token, cache, cache_len, policy)

    out_shardings = (None, cspec)
    return LoweringPlan(
        cfg, shape, policy, serve_step,
        (psds, spec_in["token"], csds, spec_in["cache_len"]),
        _compat_shardings((pspec, tok_spec, cspec, P()), mesh),
        _compat_shardings(out_shardings, mesh), "decode",
    )
