"""Roofline term derivation from a compiled dry-run artifact (deliverable g).

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

``cost_analysis`` supplies FLOPs and bytes accessed; collective bytes are
NOT in cost_analysis, so :func:`collective_bytes` parses the post-SPMD HLO
text and sums the result-shape sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op (counting each op once;
result size is the standard per-chip traffic proxy).  Constants: TPU v5e —
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  bf16[8,4096,128]{2,1,0}  or  f32[]
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective-kind byte totals from post-optimization HLO text."""
    totals: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if " = " not in stripped:
            continue
        lhs, rhs = stripped.split(" = ", 1)
        kind = None
        for c in _COLLECTIVES:
            # match 'all-reduce(' or 'all-reduce-start(' etc.
            if re.match(rf"^(\(|\w|\[|,|\s)*{re.escape(c)}(-start)?\(", rhs) or rhs.startswith(
                f"{c}("
            ) or f" {c}(" in f" {rhs.split('(')[0]}(":
                kind = c
                break
        if kind is None:
            # cheap prefix check on the op name segment
            op = rhs.split("(")[0].strip()
            for c in _COLLECTIVES:
                if op.endswith(c) or op.endswith(c + "-start"):
                    kind = c
                    break
        if kind is None:
            continue
        # Result shapes appear in the RHS type annotation before the op name,
        # e.g. `bf16[8,128]{1,0} all-reduce(...)`; for tuple results all
        # element shapes are listed.  Parse shapes from the RHS up to the op.
        head = rhs.split(kind)[0]
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))
        if nbytes == 0:
            # fall back: shapes may be on the LHS in some printers
            nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(lhs))
        totals[kind] += float(nbytes)
    totals["total"] = float(sum(totals[k] for k in _COLLECTIVES))
    return totals


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, float]
    model_flops: float
    bytes_per_device: float
    peak_memory_per_device: float

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        # collective_bytes from the partitioned HLO is already per-chip
        # traffic (the module is the per-device program).
        return self.coll_bytes / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        if self.hlo_flops <= 0:
            return 0.0
        return self.model_flops / self.hlo_flops

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "bytes_per_device": self.bytes_per_device,
            "peak_memory_per_device": self.peak_memory_per_device,
        }


def model_flops_estimate(cfg, shape, n_params: int, n_active: Optional[int] = None) -> float:
    """MODEL_FLOPS = 6 N D (train) / 2 N D (inference) with N = active params.

    D is tokens processed: B*S for train/prefill, B for one decode step.
    """
    n = n_active if n_active is not None else n_params
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch


def active_params(cfg, n_params: int) -> int:
    """MoE: only top-k (+ shared) experts are active per token."""
    if cfg.moe_num_experts <= 0:
        return n_params
    f, d, e = cfg.moe_d_ff, cfg.d_model, cfg.moe_num_experts
    per_expert = 3 * d * f
    routed_total = cfg.num_layers * e * per_expert
    routed_active = cfg.num_layers * cfg.moe_top_k * per_expert
    return n_params - routed_total + routed_active
