"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
which undercounts every ``lax.scan``-stacked layer loop by its trip count —
useless for roofline work on scan-based models.  This module re-derives
FLOPs / HBM bytes / collective bytes by walking the post-optimization HLO
text as a call graph:

* per computation: dot/convolution FLOPs (operand shapes resolved through a
  per-computation symbol table), per-op traffic (result + operand bytes,
  skipping free ops), and collective result bytes by kind;
* ``fusion`` ops contribute their callee's FLOPs but only the fusion's own
  operand/result bytes (the interior is fused — no HBM traffic);
* ``while`` ops multiply body+condition cost by the trip count parsed from
  ``backend_config={"known_trip_count":{"n":...}}`` (fallback: the constant
  bound in the condition computation, else 1);
* async collective pairs are counted at the ``-done`` op only.

Bytes are a traffic *model* (each op's operands + results), deliberately
close to what HloCostAnalysis charges; collective bytes use the result-shape
size, the standard per-chip traffic proxy.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _parse_shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _bytes_of(shapes: List[Tuple[str, Tuple[int, ...]]]) -> int:
    total = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS}
    )

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        for k in COLLECTIVE_KINDS:
            self.coll[k] += mult * other.coll[k]

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


@dataclasses.dataclass
class _Op:
    name: str
    result_text: str          # type annotation part of the RHS
    opcode: str
    line: str


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[_Op]] = {}
        self.symtab: Dict[str, Dict[str, str]] = {}  # comp -> op name -> result text
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}
        self.entry = self._entry_name(hlo_text)

    # -------------------------------------------------------------- parsing
    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            hdr = _COMP_HDR_RE.match(line.strip())
            if hdr and line.strip().endswith("{"):
                cur = hdr.group(1)
                self.computations[cur] = []
                self.symtab[cur] = {}
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            # opcode = first identifier followed by '(' after the type annot.
            om = re.search(r"([a-z][\w\-]*)\(", rhs)
            opcode = om.group(1) if om else ""
            # result text = everything before the opcode occurrence
            result_text = rhs[: om.start()] if om else rhs
            self.computations[cur].append(_Op(name, result_text, opcode, line))
            self.symtab[cur][name] = result_text

    def _entry_name(self, text: str) -> str:
        for line in text.splitlines():
            s = line.strip()
            if s.startswith("ENTRY"):
                m = _COMP_HDR_RE.match(s)
                if m:
                    return m.group(1)
        # fallback: last computation
        return list(self.computations)[-1]

    # ------------------------------------------------------------- costing
    def cost(self, comp: Optional[str] = None) -> Cost:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        total = Cost()
        self._memo[comp] = total  # break cycles defensively
        for op in self.computations.get(comp, []):
            self._cost_op(comp, op, total)
        return total

    def _operand_bytes_list(self, comp: str, op: _Op) -> list:
        # operands appear after the opcode '('; resolve through the symtab.
        after = op.line.split(f"{op.opcode}(", 1)
        if len(after) < 2:
            return []
        args = after[1].split(")", 1)[0]
        out = []
        for ref in _OPERAND_RE.findall(args):
            text = self.symtab[comp].get(ref)
            if text:
                out.append(_bytes_of(_parse_shapes(text)))
        return out

    def _operand_bytes(self, comp: str, op: _Op) -> int:
        return sum(self._operand_bytes_list(comp, op))

    def _dot_flops(self, comp: str, op: _Op) -> float:
        result = _parse_shapes(op.result_text)
        out_elems = 1
        for _dt, shape in result[:1]:
            for d in shape:
                out_elems *= d
        # contraction size from the lhs operand + lhs_contracting_dims
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
        dims = [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []
        after = op.line.split("dot(", 1)
        contraction = 1
        if len(after) == 2 and dims:
            first = _OPERAND_RE.findall(after[1].split(")", 1)[0])
            if first:
                text = self.symtab[comp].get(first[0], "")
                shapes = _parse_shapes(text)
                if shapes:
                    shape = shapes[0][1]
                    for d in dims:
                        if d < len(shape):
                            contraction *= shape[d]
        return 2.0 * out_elems * contraction

    def _conv_flops(self, comp: str, op: _Op) -> float:
        result = _parse_shapes(op.result_text)
        out_elems = 1
        for _dt, shape in result[:1]:
            for d in shape:
                out_elems *= d
        # kernel operand is the 2nd argument
        after = op.line.split("convolution(", 1)
        if len(after) < 2:
            return 0.0
        refs = _OPERAND_RE.findall(after[1].split(")", 1)[0])
        if len(refs) < 2:
            return 0.0
        ksh = _parse_shapes(self.symtab[comp].get(refs[1], ""))
        if not ksh:
            return 0.0
        kshape = ksh[0][1]
        # FLOPs = 2 * out_elems * (kernel elements / output channels)
        kelems = 1
        for d in kshape:
            kelems *= d
        out_ch = kshape[-1] if kshape else 1
        return 2.0 * out_elems * (kelems / max(out_ch, 1))

    def _trip_count(self, op: _Op, cond: str) -> int:
        m = _TRIP_RE.search(op.line)
        if m:
            return int(m.group(1))
        # fallback: largest integer constant in the condition computation
        best = 1
        for c_op in self.computations.get(cond, []):
            cm = re.search(r"constant\((\d+)\)", c_op.line)
            if cm:
                best = max(best, int(cm.group(1)))
        return best

    def _cost_op(self, comp: str, op: _Op, total: Cost) -> None:
        code = op.opcode
        if code in _FREE_OPS or not code:
            return
        base = code[:-6] if code.endswith("-start") else (
            code[:-5] if code.endswith("-done") else code
        )
        if base in COLLECTIVE_KINDS:
            if code.endswith("-start"):
                return  # counted at -done
            nbytes = _bytes_of(_parse_shapes(op.result_text))
            total.coll[base] += float(nbytes)
            total.bytes += float(nbytes)
            return
        if code == "while":
            m = _COND_BODY_RE.search(op.line)
            if m:
                cond, body = m.group(1), m.group(2)
                trip = self._trip_count(op, cond)
                total.add(self.cost(body), trip)
                total.add(self.cost(cond), trip)
            return
        if code in ("call", "custom-call"):
            m = _CALLS_RE.search(op.line)
            if m:
                total.add(self.cost(m.group(1)))
            total.bytes += _bytes_of(_parse_shapes(op.result_text))
            return
        if code == "conditional":
            for branch in re.findall(r"branch_computations=\{([^}]*)\}", op.line):
                for b in _OPERAND_RE.findall(branch):
                    total.add(self.cost(b))
            return
        if code == "dynamic-update-slice" or (
            code == "fusion" and "dynamic-update-slice" in op.line
        ):
            # In-place update: traffic is the update slice (read + write),
            # not the whole aliased buffer.  Charge operands minus the
            # largest (the buffer), twice.
            if code == "fusion":
                m = _CALLS_RE.search(op.line)
                if m:
                    total.flops += self.cost(m.group(1)).flops
            opb = self._operand_bytes_list(comp, op)
            if opb:
                total.bytes += 2.0 * (sum(opb) - max(opb))
            return
        if code == "dynamic-slice":
            # Reads only the slice: charge slice read + write.
            total.bytes += 2.0 * _bytes_of(_parse_shapes(op.result_text))
            return
        if code == "fusion":
            m = _CALLS_RE.search(op.line)
            if m:
                callee = self.cost(m.group(1))
                total.flops += callee.flops  # interior bytes are fused away
            res = _bytes_of(_parse_shapes(op.result_text))
            total.bytes += res
            if "kind=kLoop" in op.line:
                # Elementwise loop fusion: each operand contributes at most
                # one output-shaped read (slices of big stacked buffers —
                # e.g. per-layer weight picks — read only what they use).
                total.bytes += sum(min(b, res) for b in
                                   self._operand_bytes_list(comp, op))
            else:
                # kInput/kOutput (reduction) fusions read inputs fully.
                total.bytes += self._operand_bytes(comp, op)
            return
        if code == "dot":
            total.flops += self._dot_flops(comp, op)
            total.bytes += _bytes_of(_parse_shapes(op.result_text))
            total.bytes += self._operand_bytes(comp, op)
            return
        if code == "convolution":
            total.flops += self._conv_flops(comp, op)
            total.bytes += _bytes_of(_parse_shapes(op.result_text))
            total.bytes += self._operand_bytes(comp, op)
            return
        # generic op: traffic only
        total.bytes += _bytes_of(_parse_shapes(op.result_text))
        total.bytes += self._operand_bytes(comp, op)


def analyze_hlo(hlo_text: str) -> Dict[str, float]:
    """Top-level helper: trip-count-aware flops / bytes / collective bytes."""
    model = HloCostModel(hlo_text)
    c = model.cost()
    out = {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_bytes": c.coll_total,
    }
    out.update({f"coll_{k}": v for k, v in c.coll.items()})
    return out


def collective_breakdown(hlo_text: str) -> Dict[str, float]:
    """Per-kind collective result bytes of one compiled HLO module.

    Keys are HLO op kinds (:data:`COLLECTIVE_KINDS`), values trip-count-aware
    byte totals — the calibration source for the serving cost model's
    per-collective terms (``ExecutionStats.add_collectives``).
    """
    return dict(HloCostModel(hlo_text).cost().coll)
