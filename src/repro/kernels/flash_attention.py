"""Pallas TPU flash attention (online-softmax, causal/sliding-window).

Target: TPU v5e.  Grid ``(batch*kv_heads*q_groups, S/q_blk, T/kv_blk)`` with
the KV axis innermost — TPU grids execute sequentially, so the running
softmax statistics live in VMEM scratch across KV steps and the output tile
is finalised on the last KV step.  Block shapes keep the working set in
VMEM: ``q_blk x d`` + ``kv_blk x d`` tiles plus an ``q_blk x kv_blk`` score
tile, all multiples of 128 on the matmul dims for MXU alignment.

This container is CPU-only: the kernel is validated with
``interpret=True`` against :func:`repro.kernels.ref.flash_attention_ref`
(and the model-side oracle ``repro.models.layers.attention_chunked``).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,          # (1, q_blk, d), (1, kv_blk, d) VMEM tiles
    o_ref,                        # (1, q_blk, d)
    m_scr, l_scr, acc_scr,        # VMEM scratch: (q_blk,), (q_blk,), (q_blk, d)
    *,
    sm_scale: float,
    q_blk: int,
    kv_blk: int,
    kv_len: int,
    causal: bool,
    window: Optional[int],
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * sm_scale          # (q_blk, d)
    k = k_ref[0].astype(jnp.float32)                     # (kv_blk, d)
    s = q @ k.T                                          # (q_blk, kv_blk)

    q_pos = qi * q_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 0)
    k_pos = ki * kv_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_scr[...] * alpha + p.sum(axis=1)
    acc = acc_scr[...] * alpha[:, None] + p @ v_ref[0].astype(jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(
            o_ref.dtype
        )


def flash_attention(
    q: jax.Array,                 # (BH, S, d) queries, flattened batch*heads
    k: jax.Array,                 # (BH, T, d)
    v: jax.Array,                 # (BH, T, d)
    causal: bool = True,
    window: Optional[int] = None,
    q_blk: int = 128,
    kv_blk: int = 128,
    interpret: bool = True,       # CPU container: interpret by default
) -> jax.Array:
    """Pallas flash attention over flattened (batch*heads) slices.

    Sequence lengths are padded to the block sizes; padding keys are masked
    by the in-kernel ``k_pos < kv_len`` guard and padded queries sliced off.
    """
    bh, s, d = q.shape
    t = k.shape[1]
    sm_scale = 1.0 / math.sqrt(d)
    s_pad = (s + q_blk - 1) // q_blk * q_blk
    t_pad = (t + kv_blk - 1) // kv_blk * kv_blk
    if s_pad != s:
        q = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0)))
    if t_pad != t:
        k = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0)))

    grid = (bh, s_pad // q_blk, t_pad // kv_blk)
    kernel = functools.partial(
        _flash_kernel,
        sm_scale=sm_scale, q_blk=q_blk, kv_blk=kv_blk,
        kv_len=t, causal=causal, window=window,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_blk, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kv_blk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_blk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_blk, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk,), jnp.float32),
            pltpu.VMEM((q_blk,), jnp.float32),
            pltpu.VMEM((q_blk, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :s]
