"""Pallas TPU kernel for the pairwise inverse-Pearson profile (paper §3.1
Step 1) — the compute hot spot of Antler's affinity analysis.

After row standardisation (done in the jnp wrapper: subtract mean, scale to
unit norm), the K x K Pearson matrix is the Gram matrix ``Z Z^T``; the
kernel is a tiled MXU matmul over the feature axis with the ``1 - r``
epilogue fused into the last reduction step.  Grid
``(K/blk_i, K/blk_j, F/blk_f)`` with the feature axis innermost and an fp32
VMEM accumulator carried across feature steps.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve an ``interpret`` override against the active backend.

    ``None`` (the default) selects the real Mosaic pipeline on TPU and the
    interpreter everywhere else (CPU/GPU containers, where the TPU dialect
    cannot lower) — so the same call sites run fast on TPU without silently
    interpreting there.  An explicit ``True``/``False`` always wins (tests
    pin the interpreter for hermetic CPU runs).
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _gram_kernel(zi_ref, zj_ref, o_ref, acc_scr, *, nf: int):
    """One (blk_i x blk_j) dissimilarity tile, accumulated over feature blocks."""
    fi = pl.program_id(2)

    @pl.when(fi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += zi_ref[...].astype(jnp.float32) @ zj_ref[...].astype(
        jnp.float32
    ).T

    @pl.when(fi == nf - 1)
    def _finalize():
        o_ref[...] = (1.0 - acc_scr[...]).astype(o_ref.dtype)


def pearson_dissimilarity(
    z: jax.Array,          # (K, F) — rows already centered + unit-normalised
    blk_k: int = 128,
    blk_f: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """``1 - Z Z^T`` with VMEM tiling.  Returns (K, K) fp32.

    ``interpret=None`` resolves from the backend (Mosaic on TPU, interpreter
    elsewhere); pass an explicit bool to override.
    """
    interpret = resolve_interpret(interpret)
    k, f = z.shape
    k_pad = (k + blk_k - 1) // blk_k * blk_k
    f_pad = (f + blk_f - 1) // blk_f * blk_f
    if (k_pad, f_pad) != (k, f):
        z = jnp.pad(z, ((0, k_pad - k), (0, f_pad - f)))
    grid = (k_pad // blk_k, k_pad // blk_k, f_pad // blk_f)
    out = pl.pallas_call(
        functools.partial(_gram_kernel, nf=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_k, blk_f), lambda i, j, fi: (i, fi)),
            pl.BlockSpec((blk_k, blk_f), lambda i, j, fi: (j, fi)),
        ],
        out_specs=pl.BlockSpec((blk_k, blk_k), lambda i, j, fi: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k_pad, k_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((blk_k, blk_k), jnp.float32)],
        interpret=interpret,
    )(z, z)
    return out[:k, :k]
