"""Pallas TPU kernels (validated interpret=True on CPU) + jnp oracles."""
from repro.kernels.ops import (
    flash_attention_bhsd,
    pairwise_pearson_dissimilarity,
    ssd_scan,
)
