"""Pallas TPU kernels (validated interpret=True on CPU) + jnp oracles.

The affinity kernel resolves its backend automatically (see
:func:`repro.kernels.pearson_affinity.resolve_interpret`): Mosaic on TPU,
interpreter elsewhere, explicit override for tests.
"""
from repro.kernels.ops import (
    flash_attention_bhsd,
    pairwise_pearson_dissimilarity,
    ssd_scan,
)
from repro.kernels.pearson_affinity import resolve_interpret
