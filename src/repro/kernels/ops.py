"""Jit'd public wrappers around the Pallas kernels.

Each op normalises layouts (e.g. (B,S,H,D) -> flattened (B*H,S,D) slices for
attention), handles GQA head grouping, picks block sizes, and exposes an
``interpret`` flag.  For the affinity kernel ``interpret`` defaults to
``None`` and is resolved from the active backend (Mosaic on TPU, interpreter
on CPU/GPU); the attention/scan kernels still default to the interpreter
pending the same treatment on a real TPU target.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.pearson_affinity import pearson_dissimilarity as _pearson
from repro.kernels.ssd_scan import ssd_scan as _ssd


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_blk", "kv_blk", "interpret")
)
def flash_attention_bhsd(
    q: jax.Array,   # (B, S, Hq, D)
    k: jax.Array,   # (B, T, Hk, D)
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    q_blk: int = 128,
    kv_blk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """GQA flash attention in model layout: repeats KV heads to match Q."""
    b, s, hq, d = q.shape
    hk = k.shape[2]
    rep = hq // hk
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hq, -1, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hq, -1, d)
    of = _flash(qf, kf, vf, causal=causal, window=window,
                q_blk=q_blk, kv_blk=kv_blk, interpret=interpret)
    return of.reshape(b, hq, s, d).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("blk_k", "blk_f", "interpret"))
def pairwise_pearson_dissimilarity(
    feats: jax.Array,   # (K, F) raw representations of K samples
    blk_k: int = 128,
    blk_f: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Standardise rows then run the tiled ``1 - Gram`` kernel (fp32).

    ``interpret=None`` resolves from ``jax.default_backend()`` (Mosaic on
    TPU, interpreter elsewhere); an explicit bool overrides.
    """
    z = feats.astype(jnp.float32)
    z = z - jnp.mean(z, axis=-1, keepdims=True)
    z = z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-8)
    return _pearson(z, blk_k=blk_k, blk_f=blk_f, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array, dt: jax.Array, a: jax.Array,
    b_in: jax.Array, c_in: jax.Array,
    chunk: int = 128,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    return _ssd(x, dt, a, b_in, c_in, chunk=chunk, interpret=interpret)
