"""Pallas TPU kernel for the Mamba2 chunked SSD scan (arXiv:2405.21060).

One grid step processes one (batch, chunk) tile for ALL heads: the
intra-chunk quadratic term (masked-decay attention over the chunk) and the
inter-chunk state recurrence, with the running (H, P, N) state carried in
VMEM scratch across the sequential chunk axis.  Grid ``(B, S/Q)`` with the
chunk axis innermost; the state scratch is re-zeroed at chunk 0 of every
batch row.

Per-tile working set (fp32): Q*H*P (x) + Q*N (B,C) + H*P*N (state) + Q*Q*H
(decay tile) — sized to sit comfortably in 128 MB-class VMEM for
(Q=128, H<=96/16 per model shard, P=64, N=128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,      # (1, Q, H, P)
    dt_ref,     # (1, Q, H)
    a_ref,      # (H,)
    b_ref,      # (1, Q, N)
    c_ref,      # (1, Q, N)
    y_ref,      # (1, Q, H, P)
    fin_ref,    # (1, H, P, N) final state output (written on last chunk)
    state_scr,  # VMEM (H, P, N) running inter-chunk state
    *,
    q: int,
    nc: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _reset():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, H, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, H)
    a = a_ref[...].astype(jnp.float32)        # (H,)
    bb = b_ref[0].astype(jnp.float32)         # (Q, N)
    cc = c_ref[0].astype(jnp.float32)         # (Q, N)

    da = dt * a[None, :]                      # (Q, H)
    da_cum = jnp.cumsum(da, axis=0)           # inclusive

    # Intra-chunk: y_i = sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) dt_j x_j
    cb = cc @ bb.T                            # (Q, Q)
    decay = jnp.exp(da_cum[:, None, :] - da_cum[None, :, :])      # (Q, Q, H)
    causal = (
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    )
    lmat = jnp.where(causal[:, :, None], decay, 0.0) * cb[:, :, None]  # (Q,Q,H)
    dx = dt[:, :, None] * x                    # (Q, H, P)
    y = jnp.einsum("ijh,jhp->ihp", lmat, dx)

    # Inter-chunk: y_i += C_i . state_prev * exp(cum_i)
    state = state_scr[...]                     # (H, P, N)
    y += jnp.einsum("in,hpn,ih->ihp", cc, state, jnp.exp(da_cum))

    # State update: state = state * exp(cum_Q) + sum_j B_j x dx_j exp(cum_Q - cum_j)
    to_end = jnp.exp(da_cum[-1][None, :] - da_cum)  # (Q, H)
    s_chunk = jnp.einsum("jn,jh,jhp->hpn", bb, to_end, dx)
    state_scr[...] = state * jnp.exp(da_cum[-1])[:, None, None] + s_chunk

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _final():
        fin_ref[0] = state_scr[...].astype(fin_ref.dtype)


def ssd_scan(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H)
    a: jax.Array,      # (H,)
    b_in: jax.Array,   # (B, S, N)
    c_in: jax.Array,   # (B, S, N)
    chunk: int = 128,
    interpret: bool = True,
):
    """Chunked SSD.  Returns (y (B,S,H,P), final_state (B,H,P,N) fp32)."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    if s % chunk != 0:
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    s_pad = x.shape[1]
    nc = s_pad // chunk
    grid = (bsz, nc)
    y, fin = pl.pallas_call(
        functools.partial(_ssd_kernel, q=chunk, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, h), lambda b, c: (b, c, 0)),
            pl.BlockSpec((h,), lambda b, c: (0,)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s_pad, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((h, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b_in, c_in)
    return y[:, :s], fin
