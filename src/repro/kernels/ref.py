"""Pure-jnp oracles for every Pallas kernel (shape/dtype-swept in tests)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked, ssd_sequential_ref


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array,
    causal: bool = True, window: Optional[int] = None,
) -> jax.Array:
    """Dense softmax attention over flattened (batch*heads) slices."""
    d = q.shape[-1]
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(d)
    qp = jnp.arange(q.shape[1])[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones(s.shape[1:], dtype=bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,btd->bsd", w, v.astype(jnp.float32)).astype(q.dtype)


def pearson_dissimilarity_ref(z: jax.Array) -> jax.Array:
    """``1 - Z Z^T`` for standardised rows (fp32)."""
    z32 = z.astype(jnp.float32)
    return 1.0 - z32 @ z32.T


def ssd_scan_ref(
    x: jax.Array, dt: jax.Array, a: jax.Array,
    b_in: jax.Array, c_in: jax.Array, chunk: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked-SSD oracle (itself validated against the sequential scan)."""
    return ssd_chunked(x, dt, a, b_in, c_in, chunk)


def ssd_sequential(x, dt, a, b_in, c_in):
    return ssd_sequential_ref(x, dt, a, b_in, c_in)
