#!/usr/bin/env bash
# Tier-1 gate: the full test suite must collect and pass, and the batched
# serving benchmark must run its equivalence checks in --dry-run mode.
# Catches collection regressions (like the seed's missing-hypothesis import
# errors) before merge.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
python benchmarks/serving_batch.py --dry-run
# Multi-group warm-start sweep: warm-vs-cold equivalence, exact counters,
# fused single-dispatch, and the >= 1.5x load-reduction gate.
python benchmarks/serving_groups.py --dry-run
# Admission-policy sweep: sessioned-vs-sequential equivalence, exact
# incremental counters, and the >= 1.2x affinity-vs-window load gate.
python benchmarks/serving_admission.py --dry-run
# Mesh-sharded serving sweep: sharded-vs-single-device equivalence, exact
# collective-inclusive counters vs HLO measurement, and the >= 1.2x
# modelled sharded-speedup gate on a forced 8-device CPU mesh.
python benchmarks/serving_mesh.py --dry-run
# Chaos sweep: fault-injected multi-tenant serving — zero stranded futures,
# chaos-vs-fault-free output equivalence, exact counters through rollbacks
# and retries, and the >= 0.8x goodput gate under ~10% injected faults.
python benchmarks/serving_chaos.py --dry-run
# Weight-streaming sweep: streamed-vs-synchronous output equivalence, exact
# counters including prefetched_bytes / stream_stall_seconds, the <= 0.5x
# stall-vs-sync-load gate, and the >= 1.2x modelled-speedup gate.
python benchmarks/serving_streaming.py --dry-run
# Intermittent-power sweep: ~20 injected power failures with zero lost or
# duplicated responses, recovered-vs-uninterrupted output equivalence, exact
# counters including checkpoint_bytes / checkpoint_seconds, and the >= 1.5x
# re-executed-compute-joules gate for checkpointed resume vs restart.
python benchmarks/serving_intermittent.py --dry-run
# Input-adaptive sweep: confidence-gated vs all-blocks-floor serving on a
# mixed easy/hard Poisson trace — exact counters in both arms, >= 1.3x
# modelled per-request speedup, >= 99% argmax agreement, and calibrated
# expected flops within 5% of realized.
python benchmarks/serving_adaptive.py --dry-run
