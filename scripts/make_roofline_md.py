"""Render the §Roofline markdown table from reports/dryrun/*.json
(and the baseline snapshot for before/after comparison)."""
import glob
import json
import os
import sys


def rows(d):
    out = {}
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(f))
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def fmt(r):
    if r is None:
        return "—"
    if r["status"] == "skipped":
        return "skip"
    return f"{r['t_compute']:.3f} / {r['t_memory']:.2f} / {r['t_collective']:.2f}"


def main():
    cur = rows("reports/dryrun")
    print("| arch | shape | mesh | policy | t_compute (s) | t_memory (s) | "
          "t_collective (s) | dominant | MODEL/HLO FLOPs | params (B) |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for (a, s, m), r in sorted(cur.items()):
        if r["status"] == "skipped":
            print(f"| {a} | {s} | {m} | — | — | — | — | skipped ({r['reason'][:40]}…) | — | — |")
            continue
        print(
            f"| {a} | {s} | {m} | {r['policy']} | {r['t_compute']:.4f} | "
            f"{r['t_memory']:.3f} | {r['t_collective']:.3f} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.3f} | {r['n_params']/1e9:.2f} |"
        )


if __name__ == "__main__":
    main()
