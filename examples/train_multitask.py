"""End-to-end driver: train a ~100M-parameter multitask transformer for a
few hundred steps (deliverable b).

The backbone is a reduced granite-family decoder (~100M params); Antler's
task graph attaches 4 classification branches over its blocks, selected by
the affinity/tradeoff pipeline; the joint branched-multitask loss retrains
the graph (paper §2.2 step "the task graph is retrained") while the LM head
keeps next-token loss on the shared trunk.

Run:  PYTHONPATH=src python examples/train_multitask.py --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MSP430, TPU_V5E, GraphCostModel, optimal_order
from repro.core.task_graph import TaskGraph
from repro.data import lm_batches
from repro.models import make_config
from repro.models.multitask import (
    build_transformer_program, multitask_loss, program_trainable_params,
    transformer_block_costs, _split_layers,
)
from repro.sharding.policy import TP_POLICY
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # ~100M-param granite-family backbone (8 layers, d=768, swiglu).
    cfg = make_config(
        name="granite-100m", family="dense", num_layers=8, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32768,
        dtype="float32", param_dtype="float32", remat=False,
        attn_chunk=64, loss_chunk=64,
    )
    graph = TaskGraph.from_groups([
        [[0, 1, 2, 3]],
        [[0, 1], [2, 3]],
        [[0, 1], [2], [3]],
        [[0], [1], [2], [3]],
    ])
    n_classes = [4, 4, 8, 2]
    prog = build_transformer_program(
        jax.random.PRNGKey(0), graph, cfg, n_classes, seq_len=args.seq
    )
    flat = program_trainable_params(prog)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(flat))
    print(f"multitask transformer: {n_params/1e6:.1f}M params, "
          f"{len(prog.node_params)} task-graph nodes")

    order = optimal_order(
        GraphCostModel(prog.graph, prog.block_costs, TPU_V5E).cost_matrix()
    )
    print(f"optimal serving order for the branches: {order.order}")

    opt = adamw_init(flat)
    opt_cfg = AdamWConfig(lr=1e-4, warmup_steps=20, total_steps=args.steps)

    def loss_fn(f, x, labels):
        return multitask_loss(prog, f, x, labels)

    @jax.jit
    def train_step(f, opt, x, labels):
        loss, grads = jax.value_and_grad(loss_fn)(f, x, labels)
        f, opt, m = adamw_update(opt_cfg, grads, opt, f)
        return f, opt, loss, m["grad_norm"]

    it = lm_batches(cfg.vocab_size, batch=args.batch, seq_len=args.seq, seed=0)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for step in range(args.steps):
        tokens = jnp.asarray(next(it))
        # synthetic branch labels each task can actually learn: task t
        # classifies the token at position -(t+1) — late positions the
        # last-token head state attends to directly.  Related label spaces
        # give the branches genuine affinity.
        arr = np.asarray(tokens)
        labels = jnp.asarray(np.stack([
            arr[:, -(t + 1)] % c for t, c in enumerate(n_classes)
        ]).astype(np.int32))
        flat, opt, loss, gnorm = train_step(flat, opt, tokens, labels)
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"gnorm {float(gnorm):.3f} ({time.time()-t0:.0f}s)")
    print("done.")


if __name__ == "__main__":
    main()
