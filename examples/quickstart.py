"""Quickstart: the whole Antler pipeline on a 5-task workload in ~a minute.

1. define 5 classification tasks over one synthetic domain,
2. train per-task networks and profile task affinity (inverse Pearson +
   Spearman, paper §3.1 — with the Pallas kernel as the profiling engine),
3. enumerate task graphs, score variety vs execution cost, pick the
   tradeoff graph (paper §3.2-3.3),
4. solve the optimal task execution order (Held-Karp exact + GA, §4),
5. serve requests through the block-cached executor and compare against
   the Vanilla baseline.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    MSP430, GraphCostModel, TaskGraphExecutor, VanillaExecutor, GAConfig,
    genetic_order, optimal_order,
)
from repro.core.affinity import affinity_matrix, profile_task
from repro.core.tradeoff import select_task_graph
from repro.data import MultitaskDataset, train_test_split
from repro.models.cnn import build_lenet5_blocks
from repro.models.multitask import (
    build_cnn_program, multitask_forward, multitask_loss,
    program_trainable_params, program_with_params,
)
from repro.training.optimizer import sgd_update

N_TASKS, N_CLASSES = 5, 4


def main() -> None:
    print("== 1. tasks over a shared domain ==")
    ds = MultitaskDataset(num_tasks=N_TASKS, num_classes=N_CLASSES, seed=0)
    (xtr, ytr), (xte, yte) = train_test_split(ds, 2048, 512)
    print(f"domain X: {xtr.shape}, {N_TASKS} tasks x {N_CLASSES} classes")

    print("== 2. per-task training + affinity profiling ==")
    # Train each task independently on its own fully-separate program.
    from repro.core import TaskGraph
    sep = TaskGraph.fully_separate(N_TASKS, 3)
    prog = build_cnn_program(jax.random.PRNGKey(0), sep, [N_CLASSES] * N_TASKS)
    flat = program_trainable_params(prog)
    loss_grad = jax.jit(jax.value_and_grad(
        lambda f, x, y: multitask_loss(prog, f, x, y)))
    rng = np.random.default_rng(0)
    for step in range(200):
        idx = rng.integers(0, xtr.shape[0], size=64)
        loss, grads = loss_grad(flat, jnp.asarray(xtr[idx]), jnp.asarray(ytr[:, idx]))
        flat = sgd_update(0.05, grads, flat)
    print(f"per-task training done (final joint loss {float(loss):.3f})")

    # Profile representations at the 3 branch points over K probe samples.
    probe = jnp.asarray(xte[:64])
    trained = program_with_params(prog, flat)
    ex = TaskGraphExecutor(trained, jit_blocks=False)
    profiles = []
    for t in range(N_TASKS):
        taps, h = [], probe
        for d, node in enumerate(trained.graph.path(t)):
            h = trained.block_fns[d](trained.node_params[node], h)
            if d < 3:
                taps.append(h.reshape(h.shape[0], -1))
        profiles.append(profile_task(taps))
    aff = np.asarray(affinity_matrix(profiles))
    print("affinity S[0] (branch point 0):")
    print(np.round(aff[0], 2))

    print("== 3. task-graph selection (variety vs cost tradeoff) ==")
    _i, _a, costs, _f = build_lenet5_blocks()
    res = select_task_graph(N_TASKS, 3, aff, costs, MSP430)
    sel = res.selected
    print(f"graphs evaluated: {len(res.candidates)}")
    print(f"selected graph partitions: {sel.graph.partitions}")
    print(f"variety={sel.variety:.3f} exec_cost={sel.exec_cost*1e3:.2f} ms "
          f"storage={sel.storage_bytes/1024:.0f} KB")

    print("== 4. optimal task ordering ==")
    cm = GraphCostModel(sel.graph, costs, MSP430)
    exact = optimal_order(cm.cost_matrix())
    ga = genetic_order(cm.cost_matrix(), config=GAConfig(seed=0))
    print(f"exact order {exact.order} cost {exact.cost*1e3:.2f} ms | "
          f"GA order {ga.order} cost {ga.cost*1e3:.2f} ms")

    print("== 5. serve: block-cached executor vs Vanilla ==")
    prog2 = build_cnn_program(jax.random.PRNGKey(1), sel.graph, [N_CLASSES] * N_TASKS)
    x = jnp.asarray(xte[:8])
    ant, van = TaskGraphExecutor(prog2), VanillaExecutor(prog2)
    _, s_ant = ant.run(x, list(exact.order))
    _, s_van = van.run(x, list(exact.order))
    print(f"antler : {s_ant.blocks_executed} blocks executed, "
          f"{s_ant.blocks_skipped} skipped, {s_ant.seconds(MSP430)*1e3:.2f} ms predicted")
    print(f"vanilla: {s_van.blocks_executed} blocks executed, "
          f"{s_van.blocks_skipped} skipped, {s_van.seconds(MSP430)*1e3:.2f} ms predicted")
    print(f"speedup {s_van.seconds(MSP430)/s_ant.seconds(MSP430):.2f}x, "
          f"energy saving {100*(1-s_ant.energy(MSP430)/s_van.energy(MSP430)):.0f}%")


if __name__ == "__main__":
    main()
