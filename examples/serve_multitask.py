"""Serving example: the paper's real-world deployments (§7) as an engine.

Reproduces the audio deployment's structure: 5 tasks (presence detection,
command detection, speaker id, emotion, distance) where presence detection
is a CONDITIONAL prerequisite — the other four run only when a speaker is
present (80% of requests in the paper).  Batched requests stream through the
Antler MultitaskEngine; a Vanilla engine serves the same stream for
comparison, and the summary prints time/energy reductions (paper: 2.7-3.1x).

Also serves a batch through the LM server of a reduced granite config to
show the decode path (prefill + KV-cached greedy steps).

Run:  PYTHONPATH=src python examples/serve_multitask.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Constraints, MSP430, TaskGraph
from repro.data import MultitaskDataset
from repro.models import get_model
from repro.configs import get_smoke_config
from repro.models.multitask import build_cnn_program
from repro.serving import (
    AffinityPolicy, EnginePolicy, LMServer, MultitaskEngine, MultitaskRequest,
)
from repro.sharding.policy import TP_POLICY

TASKS = ["presence", "command", "speaker_id", "emotion", "distance"]


def main() -> None:
    print("== multitask audio deployment (paper §7.1) ==")
    # Task graph mirroring Fig. 14: presence branches early; the heavier
    # classifiers share two more blocks before splitting.
    graph = TaskGraph.from_groups([
        [[0, 1, 2, 3, 4]],
        [[0], [1, 2, 3, 4]],
        [[0], [1, 2], [3, 4]],
        [[0], [1], [2], [3], [4]],
    ])
    cons = Constraints.make(
        5, conditional=[(0, t, 0.8) for t in range(1, 5)]
    )
    prog = build_cnn_program(jax.random.PRNGKey(0), graph, [2, 11, 5, 3, 2])

    def presence_gate(outputs):
        return bool(jnp.argmax(outputs[0][0]) == 1)

    engine = MultitaskEngine(
        prog, constraints=cons, hw=MSP430,
        gates={t: presence_gate for t in range(1, 5)},
    )
    print(f"antler order: {[TASKS[t] for t in engine.order]}")

    ds = MultitaskDataset(num_tasks=5, num_classes=2, seed=1)
    total_ant = total_en = 0.0
    ran = skipped = 0
    for i in range(32):
        x, _ = ds.sample(1)
        resp = engine.serve(MultitaskRequest(x=jnp.asarray(x)))
        total_ant += resp.predicted_seconds
        total_en += resp.stats.energy(MSP430)
        ran += resp.stats.tasks_run
        skipped += resp.stats.tasks_skipped
        engine.executor.reset()  # new input -> caches invalid
    # Vanilla: every task full cost, no gating benefit beyond task skip.
    from repro.core import VanillaExecutor
    van = VanillaExecutor(prog)
    t_van = e_van = 0.0
    for i in range(32):
        x, _ = ds.sample(1)
        _, s = van.run(jnp.asarray(x), list(range(5)))
        t_van += s.seconds(MSP430)
        e_van += s.energy(MSP430)
    print(f"requests: 32 | tasks run {ran}, gated off {skipped}")
    print(f"antler  : {total_ant*1e3:8.2f} ms total, {total_en*1e3:8.2f} mJ")
    print(f"vanilla : {t_van*1e3:8.2f} ms total, {e_van*1e3:8.2f} mJ")
    print(f"reduction: {t_van/total_ant:.2f}x time, "
          f"{100*(1-total_en/e_van):.0f}% energy")

    print()
    print("== session-based serving (async admission, affinity policy) ==")
    # The same deployment served session-first: requests submit() over time
    # and return futures; AffinityPolicy admits the pending subset bucket
    # that is cheapest to resume from the executor's current residency, and
    # per-plan re-solving re-orders each group's tasks for that residency.
    sess_engine = MultitaskEngine(
        prog, hw=MSP430,
        policy=EnginePolicy(
            scheduling=AffinityPolicy(max_group_size=4, max_wait=0.05),
            resolve_order_per_plan=True,
        ),
    )
    session = sess_engine.session()
    # An adversarial arrival order: subsets alternate between the light
    # presence-only probe and the heavy full request.
    subsets = [(0,), None, (0, 1, 2), None, (0,), (3, 4), None, (1, 2)] * 2
    futures = [
        session.submit(MultitaskRequest(
            x=jnp.asarray(ds.sample(1)[0]), tasks=s))
        for s in subsets
    ]
    session.drain()
    print(f"served {len(futures)} requests in {session.groups_executed} "
          f"groups over {session.admission_rounds} admission rounds")
    print(f"executed == predicted counters: "
          f"{session.stats == session.predicted}")
    first = futures[0].result()
    print(f"first request ran order {first.effective_order} "
          f"(global order {first.order})")
    print(f"weight bytes loaded {session.stats.weight_bytes_loaded:.0f}, "
          f"skipped via residency/prefix {session.stats.weight_bytes_skipped:.0f}")

    print()
    print("== input-adaptive serving (confidence gating, expected cost) ==")
    # Early exit inside the fused suffixes: a damped-residual program whose
    # refinements vanish once a row's mean activation passes 1 (easy,
    # large-norm inputs stop paying for deep blocks), served against the
    # all-blocks floor.  EnginePolicy.adaptive is the whole opt-in; online
    # calibration then feeds the expected-cost model the solvers use.
    dim, rng = 32, np.random.default_rng(2)

    def res_block(p, h):
        return h + jnp.tanh(h @ p) * jnp.maximum(0.0, 1.0 - jnp.mean(jnp.abs(h)))

    from repro.core import BlockCost, MultitaskProgram
    from repro.serving import AdaptivePolicy

    adapt_prog = MultitaskProgram(
        graph, [res_block] * graph.depth,
        {n: jnp.asarray(rng.normal(size=(dim, dim)) / np.sqrt(dim),
                        jnp.float32) for n in graph.nodes()},
        [lambda p, h: h @ p] * 5,
        [jnp.asarray(rng.normal(size=(dim, 4)), jnp.float32)] * 5,
        [BlockCost(weight_bytes=4.0 * dim * dim, flops=2.0 * dim * dim)
         for _ in range(graph.depth)],
    )
    # 70% easy (large-norm) / 30% hard traffic, same requests to both arms.
    xs = [jnp.asarray(rng.normal(size=(dim,))
                      * (2.0 if i % 10 < 7 else 0.2), jnp.float32)
          for i in range(24)]
    arms = {}
    for name, adaptive in (
        ("floor", None),
        ("adaptive", AdaptivePolicy(threshold=0.9, calibrate_online=True)),
    ):
        eng = MultitaskEngine(adapt_prog, hw=MSP430,
                              policy=EnginePolicy(adaptive=adaptive))
        s = eng.session()
        for x in xs:
            s.submit(MultitaskRequest(x=x))
        s.drain()
        arms[name] = s
    floor_s, ad_s = arms["floor"], arms["adaptive"]
    print(f"gated off {ad_s.stats.block_rows_gated:.0f} block-rows "
          f"({ad_s.stats.flops_gated:.0f} flops never paid)")
    print(f"modelled per-request speedup vs all-blocks floor: "
          f"{floor_s.stats.seconds(MSP430) / ad_s.stats.seconds(MSP430):.2f}x")
    print(f"executed == predicted counters (trace-replayed): "
          f"{ad_s.stats == ad_s.predicted}")
    print(f"a-priori expected flops {ad_s.expected.flops_executed:.0f} vs "
          f"realized {ad_s.stats.flops_executed:.0f} "
          f"(calibrating online toward the realized mean)")

    print()
    print("== LM serving path (prefill + KV-cached decode) ==")
    cfg = get_smoke_config("granite-34b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    srv = LMServer(model, params, TP_POLICY)
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.raw_vocab_size, (4, 12)),
        jnp.int32,
    )
    t0 = time.time()
    out = srv.generate(prompts, steps=16)
    print(f"generated {out.shape} tokens in {time.time()-t0:.1f}s "
          f"(batch 4, greedy, reduced granite config)")
    print("sample:", out[0][:10])


if __name__ == "__main__":
    main()
